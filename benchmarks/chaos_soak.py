"""Chaos soak: seeded fault injection against the resilient serving stack.

    PYTHONPATH=src python benchmarks/chaos_soak.py
    PYTHONPATH=src python benchmarks/chaos_soak.py --smoke --trace t.json
    PYTHONPATH=src python benchmarks/chaos_soak.py --seed 7 --frames 1000

Drives the FrameEngine and the VideoEngine through a sustained mixed
workload while a seeded :class:`~repro.resilience.chaos.ChaosMonkey`
injects every fault type the control plane claims to survive: compile
failures (inside the PlanCache's retry boundary), executor exceptions,
malformed client frames (NaN / wrong shape / wrong dtype), cache
eviction storms, and client churn (streams closed with frames queued,
then reopened). The workload itself adds overload bursts, priority
mixes, sub-millisecond deadlines, and a rate-limit hammer, so shedding,
deadline sweeps, and token-bucket rejections all fire alongside the
injected faults.

The run PASSes only if all of the following hold:

  * **no hang** — a watchdog thread aborts the process if no progress is
    observed for ``--hang-timeout`` seconds (a wedged executor call must
    never stall the soak silently);
  * **no unhandled exception** — every fault surfaces as a structured
    outcome (RejectedFrame / ShedFrame / CancelledFrame / FailedFrame),
    never an escaped traceback;
  * **exact accounting** — per engine, both the metrics reconciliation
    identity (``offered == completed + shed + rejected + cancelled +
    failed + in_flight``, with ``in_flight == 0`` after the drain) and
    the client-side tally of received outcomes balance to zero;
  * **correct outputs** — every completed frame (including frames served
    by the fallback ladder's reference rung, and video frames that
    straddle a mid-stream rung switch) matches the pure-jnp reference
    bitwise or within ``--max-ulp`` (default 3) scale-ULPs;
  * **real chaos** — at least ``--min-faults`` injections total and at
    least one of every fault kind (a chaos harness that injects nothing
    proves nothing).

The frame phase also runs under the live telemetry plane: a
:class:`~repro.obs.telemetry.TelemetryCollector` samples the engine's
registry in the background, burn-rate SLO alert rules watch the
deadline-miss and shed budgets, and a :class:`TelemetryServer` serves
``/metrics`` which the harness scrapes mid-soak. Two telemetry gates
close the loop: the burn alert must *fire* under injected faults, and
under ``--clean`` (zero fault rates, no tight deadlines or bursts — the
negative control) the same rules must stay silent.

Writes a machine-readable ``BENCH_chaos.json`` (reconciliations, fault
counts, ULP maxima, alert states, gate verdicts); ``--trace out.json``
additionally captures the span trace (schema-validated) whose
resilience spans feed ``tools/obs_report.py --slo``, and
``--telemetry-out snap.json`` dumps the collector's ``telemetry/v1``
snapshot for ``tools/obs_report.py --alerts``.
"""
from __future__ import annotations

import argparse
import faulthandler
import json
import os
import sys
import threading
import time
import urllib.request

import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax.numpy as jnp  # noqa: E402

from repro.core.algorithms import execute_reference_video  # noqa: E402
from repro.imaging import FrameEngine, FrameRequest  # noqa: E402
from repro.kernels import ref  # noqa: E402
from repro.obs import export as obs_export  # noqa: E402
from repro.obs import trace  # noqa: E402
from repro.obs.metrics import MetricsRegistry  # noqa: E402
from repro.obs.telemetry import (TelemetryCollector,  # noqa: E402
                                 TelemetryServer, default_slo_rules)
from repro.resilience import (CancelledFrame, FailedFrame,  # noqa: E402
                              Priority, RejectedFrame, ResilienceConfig,
                              RetryPolicy, ShedFrame)
from repro.resilience.chaos import (FAULT_KINDS, ChaosMonkey,  # noqa: E402
                                    install_chaos)
from repro.video import (CompletedVideoFrame, VideoEngine,  # noqa: E402
                         VideoFrame)

SCHEMA = "bench_chaos_soak/v1"

FRAME_PIPELINES = ["denoise-m", "unsharp-m"]
VIDEO_PIPELINES = ["tmotion-t", "tunsharp-t"]

# seeded per-opportunity fault probabilities (the compile rate is raised
# to 1.0 during the scheduled blackout windows, then restored)
BASE_RATES = dict(compile=0.30, executor=0.06, nan_frame=0.04,
                  shape_frame=0.03, dtype_frame=0.03, evict_storm=0.03)


def _max_ulp(got: np.ndarray, want: np.ndarray) -> float:
    """Max ULP distance *at the array's scale* (0.0 when bitwise equal).

    The repo's correctness convention (see tests/test_executor_fuzz.py,
    tests/test_video.py): per-element ULP counts are meaningless near
    zero, so drift is measured in ULPs of the reference's largest
    magnitude. Fused-kernel FMA wobble is ~1 ULP here; a structural bug
    (wrong ring frame, bad resync, dropped tile row) is ~1e6."""
    got = np.asarray(got, np.float32)
    want = np.asarray(want, np.float32)
    if (got == want).all():
        return 0.0
    scale = float(np.spacing(np.abs(want).max() or np.float32(1.0)))
    return float(np.max(np.abs(got - want)) / scale)


class Watchdog:
    """Aborts the process when progress stalls — the no-hangs gate.

    ``kick()`` from the driver loop pushes the abort horizon out;
    a wedged executor/compile call stops the kicks, the daemon thread
    notices, dumps all stacks, and hard-exits 3 (distinct from the
    gate-failure exit 1, so CI can tell "hung" from "wrong")."""

    def __init__(self, timeout_s: float):
        self.timeout_s = timeout_s
        self._deadline = time.monotonic() + timeout_s
        self._stop = threading.Event()
        self._t = threading.Thread(target=self._watch, daemon=True,
                                   name="chaos-soak-watchdog")
        self._t.start()

    def kick(self) -> None:
        self._deadline = time.monotonic() + self.timeout_s

    def stop(self) -> None:
        self._stop.set()

    def _watch(self) -> None:
        while not self._stop.wait(1.0):
            if time.monotonic() > self._deadline:
                print(f"\nWATCHDOG: no progress for {self.timeout_s:.0f}s "
                      f"— dumping stacks and aborting", flush=True)
                faulthandler.dump_traceback()
                os._exit(3)


def _resilience(args) -> ResilienceConfig:
    return ResilienceConfig(
        default_deadline_s=30.0,     # generous catch-all SLA
        retry=RetryPolicy(max_attempts=2, base_delay_s=1e-3,
                          max_delay_s=0.02, seed=args.seed),
        breaker_failures=3, breaker_reset_s=0.25)


class Tally:
    """Client-side outcome ledger for one engine — the second half of
    the accounting gate (the engine's metrics are the first)."""

    def __init__(self):
        self.offered = 0
        self.counts = {"completed": 0, "rejected": 0, "shed": 0,
                       "cancelled": 0, "failed": 0}
        self.reject_reasons: dict[str, int] = {}
        self.shed_reasons: dict[str, int] = {}
        self.rungs: dict[str, int] = {}

    def outcome(self, c) -> None:
        if isinstance(c, RejectedFrame):
            self.counts["rejected"] += 1
            self.reject_reasons[c.reason] = \
                self.reject_reasons.get(c.reason, 0) + 1
        elif isinstance(c, ShedFrame):
            self.counts["shed"] += 1
            self.shed_reasons[c.reason] = \
                self.shed_reasons.get(c.reason, 0) + 1
        elif isinstance(c, CancelledFrame):
            self.counts["cancelled"] += 1
        elif isinstance(c, FailedFrame):
            self.counts["failed"] += 1
        else:
            self.counts["completed"] += 1
            self.rungs[c.rung] = self.rungs.get(c.rung, 0) + 1

    @property
    def balanced(self) -> bool:
        return self.offered == sum(self.counts.values())

    def snapshot(self) -> dict:
        return {"offered": self.offered, **self.counts,
                "balanced": self.balanced,
                "reject_reasons": dict(self.reject_reasons),
                "shed_reasons": dict(self.shed_reasons),
                "rungs": dict(self.rungs)}


# ------------------------------------------------------------ frame phase
def soak_frames(args, monkey: ChaosMonkey, dog: Watchdog,
                registry: MetricsRegistry | None = None) -> dict:
    """FrameEngine soak: bursty mixed-priority offered load with chaos
    corruption, tight deadlines every 13th request, oversized bursts
    every 4th round (forcing overload sheds), storms between steps, and
    a scheduled compile *blackout* (rounds 8..11: every compile fails,
    executors evicted) so the fallback ladder demonstrably serves
    frames off the reference rung and the circuit breaker trips and
    recovers — deterministic, not left to the fault dice.

    ``--clean`` inverts the phase into the telemetry negative control:
    no injected faults (the caller zeroes the monkey's rates), no tight
    deadlines, no oversized bursts, and a full drain every round — the
    same engine, workload shape, and SLO alert rules, but nothing that
    should burn error budget. The alert gates assert firing on the
    chaotic run and silence here; an alert that can't tell these runs
    apart is noise."""
    eng = FrameEngine(max_batch=4, max_pending=12,
                      resilience=_resilience(args), registry=registry)
    install_chaos(eng.cache, monkey)
    rng = np.random.default_rng(args.seed)
    h, w = args.shape
    tally = Tally()
    inputs: dict[int, dict] = {}       # rid -> clean frames of admitted reqs
    outputs: dict[int, tuple] = {}     # rid -> (pipeline, output, rung)

    def pump():
        for c in eng.step():
            tally.outcome(c)
            if not isinstance(c, (RejectedFrame, ShedFrame, FailedFrame)):
                outputs[c.rid] = (c.pipeline, np.asarray(c.output), c.rung)
        dog.kick()

    clean = getattr(args, "clean", False)
    rid = 0
    round_no = 0
    while rid < args.frames:
        burst = (16 if round_no % 4 == 3 and not clean
                 else int(rng.integers(2, 9)))
        for _ in range(min(burst, args.frames - rid)):
            pipeline = FRAME_PIPELINES[rid % len(FRAME_PIPELINES)]
            frames = {"in": rng.random((h, w), dtype=np.float32)}
            sent, _ = monkey.corrupt(frames)
            req = FrameRequest(
                rid=rid, pipeline=pipeline, frames=sent,
                priority=[Priority.LOW, Priority.NORMAL,
                          Priority.HIGH][rid % 3],
                deadline_s=5e-4 if rid % 13 == 7 and not clean else None)
            r = eng.submit(req)
            tally.offered += 1
            if r is True:
                inputs[rid] = frames       # the *clean* copy for verify
            else:
                tally.outcome(r)
            rid += 1
        if not clean:
            if round_no == 8:                   # blackout begins
                monkey.rates["compile"] = 1.0
                monkey.injected["evict_storm"] += 1
                eng.cache.evict_executors()
            elif round_no == 12:                # blackout ends
                monkey.rates["compile"] = BASE_RATES["compile"]
            monkey.maybe_storm(eng.cache)
        pump()
        while clean and eng.pending:    # negative control: no backlog,
            pump()                      # so no overload sheds
        round_no += 1
    while eng.pending or eng._shed_outbox:
        pump()

    worst = 0.0
    for r, (pipeline, out, rung) in outputs.items():
        dag = eng.cache.dag_for(pipeline)
        want = np.asarray(ref.stencil_pipeline_ref(
            dag, {k: jnp.asarray(v, jnp.float32)
                  for k, v in inputs[r].items()}))
        worst = max(worst, _max_ulp(out, want))
    dog.kick()
    return {"tally": tally.snapshot(),
            "reconciliation": eng.metrics.reconcile(),
            "max_ulp": worst, "verified": len(outputs),
            "deadline_missed": eng.metrics.deadline_missed,
            "retries": eng.metrics.executor_retries,
            "fallback_frames": eng.metrics.fallback_frames}


# ------------------------------------------------------- rate-limit phase
def soak_rate_limit(args, dog: Watchdog) -> dict:
    """Token-bucket hammer: far more submits per second than the bucket
    refills, so rate_limited rejections are guaranteed regardless of
    machine speed (the main phases leave rate limiting off — their
    reject mix must stay seeded-chaos-driven, not wall-clock-driven)."""
    cfg = _resilience(args)
    cfg.rate, cfg.burst = 40.0, 6.0
    eng = FrameEngine(max_batch=4, max_pending=32, resilience=cfg)
    rng = np.random.default_rng(args.seed + 1)
    h, w = args.shape
    tally = Tally()
    frame = {"in": rng.random((h, w), dtype=np.float32)}
    want = None
    worst = 0.0
    for i in range(48):
        r = eng.submit(FrameRequest(rid=i, pipeline=FRAME_PIPELINES[0],
                                    frames=frame))
        tally.offered += 1
        if r is not True:
            tally.outcome(r)
    while eng.pending:
        for c in eng.step():
            tally.outcome(c)
            if isinstance(c, (RejectedFrame, ShedFrame, FailedFrame)):
                continue
            if want is None:
                want = np.asarray(ref.stencil_pipeline_ref(
                    eng.cache.dag_for(c.pipeline),
                    {"in": jnp.asarray(frame["in"], jnp.float32)}))
            worst = max(worst, _max_ulp(np.asarray(c.output), want))
        dog.kick()
    return {"tally": tally.snapshot(),
            "reconciliation": eng.metrics.reconcile(), "max_ulp": worst}


# ------------------------------------------------------------ video phase
class _Stream:
    """Client-side view of one video stream: what was sent, what was
    admitted (by rid), and which rids completed, in order."""

    def __init__(self, sid: int, pipeline: str):
        self.sid = sid
        self.pipeline = pipeline
        self.sent: dict[int, dict] = {}          # rid -> clean frames
        self.completed: list[tuple[int, np.ndarray]] = []  # (rid, out)


def _verify_stream(eng: VideoEngine, st: _Stream) -> float:
    """Replay the *served* subsequence through the multi-frame oracle;
    returns the worst scale-ULP across the stream (0.0 when empty).
    Shed/rejected/cancelled/failed frames never happened to the stream,
    so the oracle sees exactly the frames the device rings saw — this is
    what makes mid-stream fallback/resync bugs visible as ULP blowups."""
    if not st.completed:
        return 0.0
    dag = eng.cache.dag_for(st.pipeline)
    videos = {k: jnp.stack([jnp.asarray(st.sent[rid][k], jnp.float32)
                            for rid, _ in st.completed])
              for k in dag.input_stages()}
    want = np.asarray(execute_reference_video(dag, videos))
    got = np.stack([out for _, out in st.completed])
    return _max_ulp(got, want)


def soak_video(args, monkey: ChaosMonkey, dog: Watchdog) -> dict:
    """VideoEngine soak: three streams fed two frames per iteration
    (mild oversubscription against a four-frame step), chaos corruption
    on the way in, tight deadlines every 11th frame (deadline sweeps on
    live streams), storms between steps, a scheduled compile blackout
    (iterations 6..9 — mid-stream reference-rung serving plus frame-ring
    resync, the hardest resilience path this engine has), scheduled
    churn (every 9th iteration closes a stream with frames queued and
    opens a replacement), and a forced final close-with-queued-frames so
    cancellation is exercised even on tiny runs."""
    eng = VideoEngine(chunk=4, max_pending=10, resilience=_resilience(args))
    install_chaos(eng.cache, monkey)
    rng = np.random.default_rng(args.seed + 2)
    h, w = args.shape
    tally = Tally()
    streams: dict[int, _Stream] = {}
    closed: list[_Stream] = []
    ulps: list[float] = []

    def open_one(i: int) -> None:
        pipeline = VIDEO_PIPELINES[i % len(VIDEO_PIPELINES)]
        sid = eng.open_stream(pipeline, h, w,
                              priority=[Priority.HIGH, Priority.NORMAL,
                                        Priority.LOW][i % 3])
        streams[sid] = _Stream(sid, pipeline)

    def close_one(sid: int) -> None:
        st = streams.pop(sid)
        for c in eng.close_stream(sid, cancel=True):
            tally.outcome(c)
        ulps.append(_verify_stream(eng, st))
        closed.append(st)

    def pump() -> None:
        for c in eng.step():
            tally.outcome(c)
            if isinstance(c, CompletedVideoFrame):
                streams[c.stream].completed.append(
                    (c.rid, np.asarray(c.output)))
        dog.kick()

    for i in range(3):
        open_one(i)
    rid = 0
    opened = 3
    iter_no = 0
    while rid < args.video_frames:
        for sid in list(streams):
            st = streams[sid]
            for _ in range(min(2, args.video_frames - rid)):
                frames = {"in": rng.random((h, w), dtype=np.float32)}
                sent, _ = monkey.corrupt(frames)
                r = eng.submit(VideoFrame(
                    sid, sent, rid=rid,
                    deadline_s=1e-3 if rid % 11 == 5 else None))
                tally.offered += 1
                if r is True:
                    st.sent[rid] = frames
                else:
                    tally.outcome(r)
                rid += 1
            if rid >= args.video_frames:
                break
        if iter_no == 6:                        # blackout begins
            monkey.rates["compile"] = 1.0
            monkey.injected["evict_storm"] += 1
            eng.cache.evict_executors()
        elif iter_no == 10:                     # blackout ends
            monkey.rates["compile"] = BASE_RATES["compile"]
        monkey.maybe_storm(eng.cache)
        pump()
        if iter_no % 9 == 4 and len(streams) > 1:
            # scheduled churn: drain-free close — whatever is queued
            # gets cancelled — then an immediate replacement stream
            monkey.injected["churn"] += 1
            close_one(next(iter(streams)))
            open_one(opened)
            opened += 1
        iter_no += 1

    # drain all but one stream; the last one closes with frames queued so
    # the cancel path is exercised deterministically
    last_sid = max(streams)
    while any(s.queue for sid, s in eng._sessions.items()
              if sid != last_sid) or eng._shed_outbox:
        pump()
    s_last = eng._sessions[last_sid]
    if not s_last.queue:        # ensure it has something to cancel
        fr = {"in": rng.random((h, w), dtype=np.float32)}
        r = eng.submit(VideoFrame(last_sid, fr, rid=rid))
        tally.offered += 1
        if r is True:
            streams[last_sid].sent[rid] = fr
        else:
            tally.outcome(r)
        rid += 1
    for sid in list(streams):
        close_one(sid)
    dog.kick()
    return {"tally": tally.snapshot(),
            "reconciliation": eng.metrics.reconcile(),
            "max_ulp": max(ulps) if ulps else 0.0,
            "verified": sum(len(st.completed) for st in closed),
            "streams_opened": opened,
            "retries": eng.metrics.executor_retries,
            "fallback_frames": eng.metrics.fallback_frames}


# ------------------------------------------------------------------ gates
def evaluate(report: dict, args) -> list[dict]:
    gates = []

    def gate(name: str, ok: bool, detail: str) -> None:
        gates.append({"name": name, "ok": bool(ok), "detail": detail})

    for phase in ("frame", "rate_limit", "video"):
        rec = report[phase]["reconciliation"]
        tal = report[phase]["tally"]
        gate(f"{phase}:metrics_balanced",
             rec["balanced"] and rec["in_flight"] == 0,
             f"offered={rec['offered']} accounted={rec['accounted']} "
             f"in_flight={rec['in_flight']}")
        gate(f"{phase}:client_balanced", tal["balanced"],
             f"offered={tal['offered']} "
             f"received={sum(tal[k] for k in ('completed', 'rejected', 'shed', 'cancelled', 'failed'))}")
        # spatial pipelines hold the tight executor-fuzz bound; temporal
        # chains inherit the documented FMA-contraction wobble bound
        # (32 ULP at scale, see tests/test_video.py) — either way a
        # resync/ladder bug shows up ~1e6 ULP, far past both gates
        bound = args.max_ulp_video if phase == "video" else args.max_ulp
        gate(f"{phase}:outputs_correct",
             report[phase]["max_ulp"] <= bound,
             f"max_ulp={report[phase]['max_ulp']:.2f} (gate {bound})")
    total_offered = sum(report[p]["tally"]["offered"]
                        for p in ("frame", "rate_limit", "video"))
    gate("workload:frames", total_offered >= args.min_frames,
         f"{total_offered} frames offered (gate {args.min_frames})")
    alerts = report.get("telemetry", {}).get("alerts", [])
    fired = {a["rule"]: a["fired_count"] for a in alerts}
    if getattr(args, "clean", False):
        # negative control: same engines, same alert rules, no chaos —
        # the SLO alerts must stay silent for the whole run
        gate("telemetry:alerts_quiet",
             bool(alerts) and all(n == 0 for n in fired.values()),
             "no alert fired" if all(n == 0 for n in fired.values())
             else "fired: " + ", ".join(r for r, n in fired.items() if n))
        gate("telemetry:endpoint_live",
             report.get("telemetry", {}).get("metrics_endpoint_ok", False),
             "live /metrics scrape parsed mid-soak")
        return gates
    faults = report["faults"]
    gate("chaos:total", sum(faults.values()) >= args.min_faults,
         f"{sum(faults.values())} faults injected (gate {args.min_faults})")
    missing = [k for k in FAULT_KINDS if not faults.get(k)]
    gate("chaos:all_kinds", not missing,
         "all kinds fired" if not missing else f"missing: {missing}")
    gate("control_plane:exercised",
         report["frame"]["tally"]["shed"] > 0
         and report["frame"]["tally"]["rejected"] > 0
         and report["rate_limit"]["tally"]["reject_reasons"]
         .get("rate_limited", 0) > 0
         and report["video"]["tally"]["cancelled"] > 0
         and (report["frame"]["fallback_frames"]
              + report["video"]["fallback_frames"]) > 0,
         "shed/reject/rate-limit/cancel/fallback all nonzero")
    burn_fired = sum(n for r, n in fired.items() if r.endswith("_burn"))
    gate("telemetry:burn_alert_fired", burn_fired > 0,
         f"{burn_fired} burn-rate firings under injected faults"
         + ("" if burn_fired else " (alert plane is blind to the burn)"))
    gate("telemetry:endpoint_live",
         report.get("telemetry", {}).get("metrics_endpoint_ok", False),
         "live /metrics scrape parsed mid-soak")
    return gates


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        description="Seeded chaos soak for the resilient serving stack")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--frames", type=int, default=600,
                    help="FrameEngine requests to offer")
    ap.add_argument("--video-frames", type=int, default=240,
                    help="VideoEngine frames to offer")
    ap.add_argument("--height", type=int, default=24)
    ap.add_argument("--width", type=int, default=32)
    ap.add_argument("--max-ulp", type=float, default=8.0,
                    help="worst allowed scale-ULP vs the reference "
                         "(spatial phases; denoise-m measures up to 5 "
                         "across batch variants on [0,1) inputs)")
    ap.add_argument("--max-ulp-video", type=float, default=32.0,
                    help="worst allowed scale-ULP for temporal streams "
                         "(the documented FMA-contraction wobble bound)")
    ap.add_argument("--min-faults", type=int, default=50)
    ap.add_argument("--min-frames", type=int, default=500)
    ap.add_argument("--hang-timeout", type=float, default=120.0,
                    help="watchdog: abort after this many seconds "
                         "without progress")
    ap.add_argument("--smoke", action="store_true",
                    help="CI gate: smaller frames/loads, same gates")
    ap.add_argument("--clean", action="store_true",
                    help="telemetry negative control: zero fault rates, "
                         "no tight deadlines/bursts — the SLO alerts "
                         "must stay quiet (chaos gates are skipped)")
    ap.add_argument("--trace", default=None, metavar="OUT_JSON",
                    help="capture a schema-validated span trace")
    ap.add_argument("--telemetry-out", default=None, metavar="OUT_JSON",
                    help="write the collector's telemetry/v1 snapshot "
                         "(series rings + alert states) here")
    ap.add_argument("--out", default="BENCH_chaos.json")
    args = ap.parse_args(argv)

    if args.smoke:
        args.frames = 380
        args.video_frames = 140
        args.height, args.width = 16, 24
    args.shape = (args.height, args.width)

    if args.trace:
        trace.enable()

    rates = ({k: 0.0 for k in BASE_RATES} if args.clean
             else dict(BASE_RATES))
    monkey = ChaosMonkey(seed=args.seed, **rates)
    dog = Watchdog(args.hang_timeout)
    # live telemetry plane over the frame phase's engine: background
    # sampler + HTTP endpoint, with the burn-rate SLO rules the gates
    # assert on (firing under chaos, silent under --clean). Only the
    # burn rules run here: the p99 queue-wait rule keys off a cumulative
    # histogram, which first-compile stalls would trip even on a clean
    # run.
    registry = MetricsRegistry()
    rules = [r for r in default_slo_rules(prefix="frame_engine",
                                          window_s=20.0)
             if r.kind == "burn_rate"]
    collector = TelemetryCollector(registry, period_s=0.2, rules=rules)
    server = TelemetryServer(collector)
    collector.start()
    server.start()
    t0 = time.perf_counter()
    report = {"schema": SCHEMA,
              "config": {"seed": args.seed, "frames": args.frames,
                         "video_frames": args.video_frames,
                         "shape": list(args.shape), "smoke": args.smoke,
                         "clean": args.clean,
                         "rates": dict(monkey.rates)}}
    report["frame"] = soak_frames(args, monkey, dog, registry=registry)
    # scrape the live endpoint mid-soak (between phases, collector and
    # engine registry still hot) and check the exposition parses
    try:
        with urllib.request.urlopen(server.url + "/metrics",
                                    timeout=5.0) as resp:
            body = resp.read().decode()
        endpoint_ok = (resp.status == 200 and "# TYPE" in body
                       and "frame_engine_frames_offered" in body
                       and "slo_alert_firing" in body)
    except OSError:
        endpoint_ok = False
    report["rate_limit"] = soak_rate_limit(args, dog)
    report["video"] = soak_video(args, monkey, dog)
    # one final sample so counter deltas from the drain are visible,
    # then freeze the alert states into the report
    collector.sample_once()
    collector.stop()
    server.stop()
    report["telemetry"] = {
        "samples": collector.samples_taken,
        "series": len(collector.rings),
        "metrics_endpoint_ok": endpoint_ok,
        "alerts": collector.alert_snapshot(),
    }
    report["faults"] = dict(monkey.injected)
    report["wall_s"] = time.perf_counter() - t0
    dog.stop()

    if args.telemetry_out:
        os.makedirs(os.path.dirname(args.telemetry_out) or ".",
                    exist_ok=True)
        with open(args.telemetry_out, "w") as f:
            json.dump(collector.snapshot(), f, indent=1)
        print(f"wrote {args.telemetry_out}")

    gates = evaluate(report, args)
    report["gates"] = gates
    report["pass"] = all(g["ok"] for g in gates)

    if args.out:
        os.makedirs(os.path.dirname(args.out) or ".", exist_ok=True)
        with open(args.out, "w") as f:
            json.dump(report, f, indent=1)

    if args.trace:
        data = obs_export.export_global_trace(args.trace,
                                              process_name="chaos_soak")
        print(f"wrote {args.trace} "
              f"({sum(e.get('ph') == 'X' for e in data['traceEvents'])} "
              f"spans)\n" + obs_export.slo_text(data))

    print(f"\nchaos soak: {report['wall_s']:.1f}s, "
          f"faults={report['faults']}")
    tl = report["telemetry"]
    print(f"  telemetry: {tl['samples']} samples over {tl['series']} "
          f"series, endpoint_ok={tl['metrics_endpoint_ok']}, alerts: "
          + (", ".join(f"{a['rule']} fired x{a['fired_count']}"
                       for a in tl["alerts"]) or "-"))
    for phase in ("frame", "rate_limit", "video"):
        t = report[phase]["tally"]
        print(f"  {phase:<11} offered={t['offered']:>4} "
              f"completed={t['completed']:>4} rejected={t['rejected']:>3} "
              f"shed={t['shed']:>3} cancelled={t['cancelled']:>3} "
              f"failed={t['failed']:>3} "
              f"max_ulp={report[phase]['max_ulp']:.2f}")
    for g in gates:
        print(f"  [{'PASS' if g['ok'] else 'FAIL'}] {g['name']}: "
              f"{g['detail']}")
    print(f"CHAOS SOAK: {'PASS' if report['pass'] else 'FAIL'}")
    if args.out:
        print(f"wrote {args.out}")
    return 0 if report["pass"] else 1


if __name__ == "__main__":
    raise SystemExit(main())
